"""Jit'd public wrappers for the Pallas kernels.

Handles padding to TPU-friendly tiles (rows to `block_n` multiples, classes /
feature dims to 128 lanes), backend dispatch (interpret=True on CPU so the
kernels execute and validate in this container; compiled on TPU), and
restores reference semantics (slicing padding back off).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.chunked_prefill import (
    chunk_blocks,
    chunked_prefill_partials_pallas,
    chunked_prefill_partials_reference,
)
from repro.kernels.decode_attention import (
    decode_attention_pallas,
    decode_attention_reference,
)
from repro.kernels.flash_attention import (
    flash_attention_pallas,
    flash_attention_reference,
)
from repro.kernels.local_attention import (
    block_sparse_attention_pallas,
    block_sparse_attention_reference,
    local_attention_pallas,
    local_attention_reference,
)
from repro.kernels.infl_scores import infl_scores_pallas
from repro.kernels.paged_attention import (
    combine_pages,
    paged_attention_partials_pallas,
    paged_attention_partials_quant_pallas,
    paged_attention_partials_quant_reference,
    paged_attention_partials_reference,
)
from repro.kernels.lr_grad import lr_grad_pallas
from repro.kernels.lr_hvp import lr_hvp_pallas
from repro.kernels.minibatch_grad import minibatch_grad_pallas
from repro.kernels.replay_correction import replay_correction_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(x, mult):
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    return jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1)), n


def _pad_dim(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _block_n_padded(n: int) -> int:
    """Row block when the caller pads rows UP to the block: prefer a LARGE
    block that divides n exactly (no padding), else a full 128-row block
    padding a partial tail tile — never degrade to tiny blocks on awkward N
    (the divisor scan stops at 64: for big N, one padded tail tile beats a
    thousand 8-row grid steps)."""
    for b in (512, 256, 128, 64):
        if n % b == 0:
            return b
    if n >= 128:
        return 128
    b = 8
    while b < n:
        b *= 2
    return b


@functools.partial(jax.jit, static_argnames=("gamma",))
def infl_scores(v, Xa, P, Y, gamma: float):
    """Fused Eq. 6 INFL score matrix [N, C] (pads to TPU tiles, slices back)."""
    C = v.shape[0]
    lane = 128 if not _interpret() else 8
    vp = _pad_dim(_pad_dim(v, 0, lane), 1, lane)
    Xp = _pad_dim(Xa, 1, lane)
    Pp = _pad_dim(P, 1, lane)
    Yp = _pad_dim(Y, 1, lane)
    # pick the block first, then pad rows up to it — padding to a multiple
    # of 1 and deriving the block from the raw row count forced block_n=1
    # (one grid step per row) on odd N
    bn = _block_n_padded(Xp.shape[0])
    Xp, n = _pad_rows(Xp, bn)
    S = infl_scores_pallas(
        vp, Xp, _pad_rows(Pp, bn)[0], _pad_rows(Yp, bn)[0], gamma,
        block_n=bn, c_actual=C, interpret=_interpret(),
    )
    return S[:n, :C]


@functools.partial(jax.jit, static_argnames=("l2",))
def lr_grad(w, Xa, Y, weights, l2: float):
    """Fused Eq. 1 batch gradient [C, d+1] (padded rows carry weight 0)."""
    C = w.shape[0]
    N = Xa.shape[0]
    lane = 128 if not _interpret() else 8
    wp = _pad_dim(_pad_dim(w, 0, lane), 1, lane)
    Xp = _pad_dim(Xa, 1, lane)
    Yp = _pad_dim(Y, 1, lane)
    bn = _block_n_padded(N)
    # padded rows get weight 0 => no contribution
    Xp, _ = _pad_rows(Xp, bn)
    Yp, _ = _pad_rows(Yp, bn)
    w8p, _ = _pad_rows(weights, bn)
    g = lr_grad_pallas(wp, Xp, Yp, w8p, 0.0, block_n=bn,
                       c_actual=C, interpret=_interpret())
    g = g * (Xp.shape[0] / N)  # kernel divided by padded N
    return g[:C, : Xa.shape[1]] + l2 * w.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("l2",))
def lr_hvp(w, v, Xa, weights, l2: float, P=None):
    """Fused Hessian-vector product H(w) v -> [C, d+1] (CG inner loop)."""
    del P  # probs are recomputed inside the fused kernel
    C = w.shape[0]
    N = Xa.shape[0]
    lane = 128 if not _interpret() else 8
    wp = _pad_dim(_pad_dim(w, 0, lane), 1, lane)
    vp = _pad_dim(_pad_dim(v, 0, lane), 1, lane)
    Xp = _pad_dim(Xa, 1, lane)
    bn = _block_n_padded(N)
    Xp, _ = _pad_rows(Xp, bn)
    w8p, _ = _pad_rows(weights, bn)
    h = lr_hvp_pallas(wp, vp, Xp, w8p, 0.0, block_n=bn,
                      c_actual=C, interpret=_interpret())
    h = h * (Xp.shape[0] / N)
    return h[:C, : Xa.shape[1]] + l2 * v.astype(jnp.float32)


def _pad_gather_rows(arrs, mult: int):
    """Row-pad arrays that will be *gathered from*: always leaves at least one
    zeroed tail row, so padded gather indices (pointing at the original row
    count) land on zeros and contribute exactly 0."""
    return [_pad_rows(a, mult)[0] if a.shape[0] % mult else
            jnp.pad(a, [(0, mult)] + [(0, 0)] * (a.ndim - 1)) for a in arrs]


@functools.partial(jax.jit, static_argnames=("l2",))
def minibatch_grad(w, Xa, Y, weights, idx, l2: float):
    """Fused gather + mini-batch gradient (constructor-phase hot op).

    Interpret mode runs the kernel UNPADDED: the body is then the same
    floating-point program as the reference scan step, which is what makes
    sgd_train/deltagrad_replay bit-identical across backends. On TPU, lanes
    pad to 128 and the gathered batch pads to sublane multiples with indices
    pointing at a zeroed row (weight 0 => exact-zero contribution)."""
    idx = idx.astype(jnp.int32)
    if _interpret():
        return minibatch_grad_pallas(w, Xa, Y, weights, idx, l2, interpret=True)
    C = w.shape[0]
    bs = idx.shape[0]
    lane = 128
    wp = _pad_dim(_pad_dim(w, 0, lane), 1, lane)
    Xp, Yp, w8p = _pad_gather_rows(
        [_pad_dim(Xa, 1, lane), _pad_dim(Y, 1, lane), weights], 8)
    idxp = jnp.pad(idx, (0, (-bs) % 8), constant_values=Xa.shape[0])
    g = minibatch_grad_pallas(wp, Xp, Yp, w8p, idxp, l2, n_batch=bs,
                              c_actual=C, interpret=False)
    return g[:C, : Xa.shape[1]]


@functools.partial(jax.jit, static_argnames=("batch_size",))
def replay_correction(w, Xa, Y_old, Y_new, w_old, w_new, ci, cm,
                      batch_size: int):
    """Fused gather + DeltaGrad-L replay correction. Same interpret-unpadded
    bit-parity contract as `minibatch_grad`; TPU row padding extends ci with
    pointers to a zeroed row and cm with zeros (exact-zero contribution)."""
    ci = ci.astype(jnp.int32)
    if _interpret():
        return replay_correction_pallas(w, Xa, Y_old, Y_new, w_old, w_new,
                                        ci, cm, batch_size, interpret=True)
    C = w.shape[0]
    r = ci.shape[0]
    lane = 128
    wp = _pad_dim(_pad_dim(w, 0, lane), 1, lane)
    Xp, Yop, Ynp, wop, wnp = _pad_gather_rows(
        [_pad_dim(Xa, 1, lane), _pad_dim(Y_old, 1, lane),
         _pad_dim(Y_new, 1, lane), w_old, w_new], 8)
    pad = (-r) % 8
    cip = jnp.pad(ci, (0, pad), constant_values=Xa.shape[0])
    cmp_ = jnp.pad(cm, (0, pad))
    g = replay_correction_pallas(wp, Xp, Yop, Ynp, wop, wnp, cip, cmp_,
                                 batch_size, c_actual=C, interpret=False)
    return g[:C, : Xa.shape[1]]


def _attn_blocks(Sq: int, Skv: int) -> tuple:
    """(block_q, block_k) for the flash kernel: the LARGEST divisor of the
    sequence length <= 128. The old `128-or-1` rule degraded every
    non-multiple-of-128 length over 128 (now routine: mid-stream join
    prefills run at arbitrary widths) to 1-row blocks — tens of thousands
    of grid cells per head; a divisor walk caps at 128 comparisons at trace
    time and only primes still fall to 1. Shared by the pallas path and the
    reference mirror so both walk the identical block decomposition — a
    precondition of the serving bit-parity contract."""
    def pick(S: int) -> int:
        for b in range(min(128, S), 0, -1):
            if S % b == 0:
                return b
        return 1

    return pick(Sq), pick(Skv)


def _flash_adapt(inner, q, k, v, qpos, kpos, spec, **extra):
    """Shared model-layout adapter for both flash forms: q [B,S,H,D] ->
    kernel layout [B,H,S,D], one block-size choice, one position cast. ONE
    function on purpose — if the two forms adapted separately, an edit to
    one side would silently break the bit-parity contract."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    bq, bk = _attn_blocks(qt.shape[2], kt.shape[2])
    o = inner(
        qt, kt, vt, qpos.astype(jnp.int32), kpos.astype(jnp.int32),
        causal=spec.causal, window=spec.window, softcap=spec.logit_softcap,
        block_q=bq, block_k=bk, **extra,
    )
    return o.transpose(0, 2, 1, 3)


def flash_attention(q, k, v, qpos, kpos, spec):
    """Model-layer adapter around the Pallas flash kernel."""
    return _flash_adapt(flash_attention_pallas, q, k, v, qpos, kpos, spec,
                        interpret=_interpret())


def flash_attention_ref(q, k, v, qpos, kpos, spec):
    """Reference-backend form of `flash_attention`: the same adapter around
    the pure-jnp blocked mirror (identical block sizes, same per-block
    floating-point program — bit-identical to the kernel)."""
    return _flash_adapt(flash_attention_reference, q, k, v, qpos, kpos, spec)


def local_attention(q, k, v, qpos, kpos, spec):
    """Model-layer adapter around the banded (sliding-window) Pallas kernel:
    the flash program with fully-masked band blocks skipped. Bitwise
    `flash_attention` for the same spec (parity rule 5)."""
    return _flash_adapt(local_attention_pallas, q, k, v, qpos, kpos, spec,
                        interpret=_interpret())


def local_attention_ref(q, k, v, qpos, kpos, spec):
    """Reference-backend form of `local_attention`: the same adapter around
    the `lax.cond`-skipping jnp mirror (identical skipped-block set —
    bit-identical to the kernel and to `flash_attention_ref`)."""
    return _flash_adapt(local_attention_reference, q, k, v, qpos, kpos, spec)


def attn_block_mask_shape(Sq: int, Skv: int) -> tuple:
    """(nq, nk) shape of the block mask `block_sparse_attention` expects for
    a [*, Sq, *, D] x [*, Skv, *, D] attention — derived from the SAME
    `_attn_blocks` decomposition the adapters pick, so callers build masks
    at exactly the kernel's block granularity."""
    bq, bk = _attn_blocks(Sq, Skv)
    return Sq // bq, Skv // bk


def block_sparse_attention(q, k, v, qpos, kpos, block_mask, spec):
    """Model-layer adapter around the block-sparse Pallas kernel: KV blocks
    with a 0 in `block_mask` ([nq, nk], see `attn_block_mask_shape`) are
    skipped; causal/window still mask elements inside enabled blocks. An
    all-ones mask is bitwise `flash_attention`."""
    return _flash_adapt(block_sparse_attention_pallas, q, k, v, qpos, kpos,
                        spec, block_mask=block_mask, interpret=_interpret())


def block_sparse_attention_ref(q, k, v, qpos, kpos, block_mask, spec):
    """Reference-backend form of `block_sparse_attention` (same skipped
    blocks via `lax.cond` — bit-identical to the kernel)."""
    return _flash_adapt(block_sparse_attention_reference, q, k, v, qpos,
                        kpos, spec, block_mask=block_mask)


def _chunked_adapt(inner, q, k, v, qpos, kpos, spec, chunk, **extra):
    """Model-layout adapter for the chunked-prefill partial forms: same
    transpose + `_attn_blocks` choice as `_flash_adapt`, but the output is
    the (m, l, acc) split-K partial triple, left in kernel layout for
    `chunked_prefill_finish` / the head-sharded partials shard_map."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    bq, bk = _attn_blocks(qt.shape[2], kt.shape[2])
    return inner(
        qt, kt, vt, qpos.astype(jnp.int32), kpos.astype(jnp.int32),
        causal=spec.causal, window=spec.window, softcap=spec.logit_softcap,
        chunk=chunk, block_q=bq, block_k=bk, **extra,
    )


def chunked_prefill_partials(q, k, v, qpos, kpos, spec, chunk: int):
    """Kernel half of the chunked-prefill op: the flash fold run chunk by
    chunk (chunk rounds up to a kv-block multiple), returning the final
    carry as singleton split-K partials m, l [B, Hq, 1, Sq], acc
    [B, Hq, 1, Sq, D] f32. Split from the merge for the same reason as
    `paged_decode_partials`: the shared `combine_pages` finish must run in
    the CALLER's context on every backend form."""
    return _chunked_adapt(chunked_prefill_partials_pallas, q, k, v, qpos,
                          kpos, spec, chunk, interpret=_interpret())


def chunked_prefill_partials_ref(q, k, v, qpos, kpos, spec, chunk: int):
    """Reference-backend form of `chunked_prefill_partials`: the same
    adapter around the per-chunk `lax.scan` mirror (identical step
    sequence — bit-identical to the chunk kernels)."""
    return _chunked_adapt(chunked_prefill_partials_reference, q, k, v, qpos,
                          kpos, spec, chunk)


def chunked_prefill_finish(m, l, acc, q):
    """Merge half of the chunked-prefill op: the SHARED `combine_pages`
    over the singleton partial (exact — the weights are exp(0) = 1.0), cast
    back to q.dtype and restored to model layout [B, Sq, Hq, D]. Bitwise
    the flash kernel's in-kernel finalize."""
    o = combine_pages(m, l, acc)  # [B, Hq, Sq, D] f32
    return o.astype(q.dtype).transpose(0, 2, 1, 3)


def chunked_prefill(q, k, v, qpos, kpos, spec, chunk: int):
    """Chunked (memory-efficient) GQA prefill: peak score-block memory
    O(Sq * chunk) instead of O(Sq * Skv), output bitwise `flash_attention`
    for ANY chunk size (see kernels/chunked_prefill.py for why)."""
    m, l, acc = chunked_prefill_partials(q, k, v, qpos, kpos, spec, chunk)
    return chunked_prefill_finish(m, l, acc, q)


def chunked_prefill_ref(q, k, v, qpos, kpos, spec, chunk: int):
    """Reference-backend form of `chunked_prefill` (same partials mirror +
    the same caller-context `combine_pages` finish)."""
    m, l, acc = chunked_prefill_partials_ref(q, k, v, qpos, kpos, spec, chunk)
    return chunked_prefill_finish(m, l, acc, q)


def _decode_layout(q, k, v):
    """Model layout -> decode-kernel layout: q [B,1,Hq,D] -> [B,Hkv,G,D];
    k, v [B,W,Hkv,D] -> [B,Hkv,W,D]. Pure transposes/reshapes (exact)."""
    B, _, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    return qg, kt, vt, G


def decode_attention(q, k, v, valid, spec):
    """Fused single-token decode attention over the ring KV cache.

    q [B,1,Hq,D]; k, v [B,W,Hkv,D] (dense, RoPE/dequant already applied);
    valid [W] slot mask (see `repro.models.attention.ring_valid`). Returns
    [B,1,Hq,D]. Interpret mode runs the kernel unpadded — the same
    floating-point program as `decode_attention_ref` — preserving the
    serving bit-parity contract; on TPU, W pads to sublane multiples with
    valid=False (exact no-ops) and the padded scale is pinned to the true
    head dim."""
    B, _, Hq, D = q.shape
    qg, kt, vt, G = _decode_layout(q, k, v)
    if _interpret():
        o = decode_attention_pallas(qg, kt, vt, valid,
                                    softcap=spec.logit_softcap, interpret=True)
        return o.reshape(B, 1, Hq, D)
    W = kt.shape[2]
    scale = D**-0.5
    qp = _pad_dim(_pad_dim(qg, 2, 8), 3, 128)
    kp = _pad_dim(_pad_dim(kt, 2, 8), 3, 128)
    vp = _pad_dim(_pad_dim(vt, 2, 8), 3, 128)
    vm = jnp.pad(valid, (0, (-W) % 8))  # padded slots masked out
    o = decode_attention_pallas(qp, kp, vp, vm, softcap=spec.logit_softcap,
                                scale=scale, interpret=False)
    return o[:, :, :G, :D].reshape(B, 1, Hq, D)


def decode_attention_ref(q, k, v, valid, spec):
    """Reference-backend form of `decode_attention`: the same layout adapter
    around the vmapped `_decode_cell` (bit-identical to the kernel)."""
    B, _, Hq, D = q.shape
    qg, kt, vt, _ = _decode_layout(q, k, v)
    o = decode_attention_reference(qg, kt, vt, valid,
                                   softcap=spec.logit_softcap)
    return o.reshape(B, 1, Hq, D)


def _paged_layout(q, k_pages):
    """Model layout -> paged-kernel layout: q [B,1,Hq,D] -> [B,Hkv,G,D].
    The page pools already carry the kernel layout ([N_pages, P, Hkv, D] —
    transposing the whole pool per decode step would copy the entire cache,
    which is exactly what the page-table indexing exists to avoid)."""
    B, _, Hq, D = q.shape
    Hkv = k_pages.shape[2]
    G = Hq // Hkv
    return q.reshape(B, Hkv, G, D), G


def paged_decode_partials(q, k_pages, v_pages, pages, pos, spec):
    """Kernel half of the paged decode op: per-page partial softmaxes
    (m, l [B, Hkv, n_pages, Gp]; acc [B, Hkv, n_pages, Gp, Dp] f32; Gp/Dp
    padded on TPU) from the page-streaming Pallas kernel. Split from the
    merge so `Backend`'s pallas_sharded form can shard_map ONLY this half:
    the shared `combine_pages` merge must run in the CALLER's execution
    context for every backend — a merge inside the jitted shard_map would
    compile its transcendentals in a different fusion context than the
    eager reference merge and drift by an ulp (the parity hazard the
    split-softmax structure exists to avoid)."""
    B, _, Hq, D = q.shape
    qg, G = _paged_layout(q, k_pages)
    pages = pages.astype(jnp.int32)
    pos = pos.astype(jnp.int32)
    if _interpret():
        return paged_attention_partials_pallas(
            qg, k_pages, v_pages, pages, pos, window=spec.window,
            softcap=spec.logit_softcap, interpret=True)
    assert k_pages.shape[1] % 8 == 0, "TPU paged cache needs page_size % 8 == 0"
    scale = D**-0.5
    qp = _pad_dim(_pad_dim(qg, 2, 8), 3, 128)
    kp = _pad_dim(k_pages, 3, 128)
    vp = _pad_dim(v_pages, 3, 128)
    return paged_attention_partials_pallas(
        qp, kp, vp, pages, pos, window=spec.window,
        softcap=spec.logit_softcap, scale=scale, interpret=False)


def paged_decode_finish(m, l, acc, q):
    """Merge half of the paged decode op: the SHARED `combine_pages` over
    the per-page partials, sliced back to the true head dims and restored
    to model layout [B, 1, Hq, D]. Every backend form calls this in the
    same (caller) context on bitwise-identical partials — which is what
    makes the three-backend equality exact."""
    B, _, Hq, D = q.shape
    Hkv = m.shape[1]
    G = Hq // Hkv
    o = combine_pages(m, l, acc)[:, :, :G, :D]
    return o.astype(q.dtype).reshape(B, 1, Hq, D)


def paged_decode_attention(q, k_pages, v_pages, pages, pos, spec):
    """Fused page-table-indexed decode attention over the paged KV cache.

    q [B,1,Hq,D]; k_pages, v_pages [N_pages, P, Hkv, D] physical pools
    (RoPE pre-applied); pages [B, n_pages] int32 block table; pos [B] int32
    per-slot decode positions. Returns [B,1,Hq,D]: the kernel streams one
    page per grid step into independent partial softmaxes
    (`paged_decode_partials`), and the shared `combine_pages` merge
    produces the output (`paged_decode_finish`). Interpret mode runs the
    kernel unpadded — the same floating-point program as
    `paged_decode_attention_ref` — preserving the serving bit-parity
    contract; on TPU, G pads to sublanes and D to 128 lanes with the scale
    pinned to the true head dim (page_size must be a sublane multiple —
    `ServeEngine` validates that at config time; `paged_decode_partials`
    carries the backstop assert for direct op callers)."""
    m, l, acc = paged_decode_partials(q, k_pages, v_pages, pages, pos, spec)
    return paged_decode_finish(m, l, acc, q)


def paged_decode_attention_ref(q, k_pages, v_pages, pages, pos, spec):
    """Reference-backend form of `paged_decode_attention`: the same layout
    adapter around the mapped `_page_partial` mirror plus the SAME
    `combine_pages` merge (bit-identical to the kernel)."""
    qg, _ = _paged_layout(q, k_pages)
    m, l, acc = paged_attention_partials_reference(
        qg, k_pages, v_pages, pages.astype(jnp.int32), pos.astype(jnp.int32),
        window=spec.window, softcap=spec.logit_softcap)
    return paged_decode_finish(m, l, acc, q)


def quant_paged_decode_partials(q, k_pages, v_pages, k_scale, v_scale,
                                pages, pos, spec):
    """Kernel half of the int8 paged decode op: per-page partials from the
    quantized page-streaming kernel (`paged_attention_partials_quant_pallas`
    — one [P, D] int8 block + one (1, 1) scale block per grid step,
    dequantized in-VMEM by the shared `_dequant_page` cell). Split from the
    merge for the same caller-context reason as `paged_decode_partials`.
    On TPU the code pools pad D to 128 lanes with ZERO codes — a zero code
    dequantizes to exactly 0.0 under any scale, so padding stays a no-op —
    while the scale arrays are never padded (the head axis is gridded, not
    blocked)."""
    B, _, Hq, D = q.shape
    qg, G = _paged_layout(q, k_pages)
    pages = pages.astype(jnp.int32)
    pos = pos.astype(jnp.int32)
    if _interpret():
        return paged_attention_partials_quant_pallas(
            qg, k_pages, v_pages, k_scale, v_scale, pages, pos,
            window=spec.window, softcap=spec.logit_softcap, interpret=True)
    assert k_pages.shape[1] % 8 == 0, "TPU paged cache needs page_size % 8 == 0"
    scale = D**-0.5
    qp = _pad_dim(_pad_dim(qg, 2, 8), 3, 128)
    kp = _pad_dim(k_pages, 3, 128)
    vp = _pad_dim(v_pages, 3, 128)
    return paged_attention_partials_quant_pallas(
        qp, kp, vp, k_scale, v_scale, pages, pos, window=spec.window,
        softcap=spec.logit_softcap, scale=scale, interpret=False)


def quant_paged_decode_attention(q, k_pages, v_pages, k_scale, v_scale,
                                 pages, pos, spec):
    """Fused int8 paged decode attention: `paged_decode_attention` with the
    page pool held as int8 codes + per-(page, head) f32 scales
    (`repro.models.attention.QuantPagedKVCache`). Same split structure —
    quantized partials, then the SHARED `combine_pages` merge in the
    caller's context — so the three-backend bitwise contract carries over
    unchanged."""
    m, l, acc = quant_paged_decode_partials(q, k_pages, v_pages, k_scale,
                                            v_scale, pages, pos, spec)
    return paged_decode_finish(m, l, acc, q)


def quant_paged_decode_attention_ref(q, k_pages, v_pages, k_scale, v_scale,
                                     pages, pos, spec):
    """Reference-backend form of `quant_paged_decode_attention`: the mapped
    quant mirror (same `_dequant_page` + `_page_partial` cells) plus the
    SAME `combine_pages` merge (bit-identical to the kernel)."""
    qg, _ = _paged_layout(q, k_pages)
    m, l, acc = paged_attention_partials_quant_reference(
        qg, k_pages, v_pages, k_scale, v_scale, pages.astype(jnp.int32),
        pos.astype(jnp.int32), window=spec.window, softcap=spec.logit_softcap)
    return paged_decode_finish(m, l, acc, q)
